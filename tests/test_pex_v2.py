"""pex v2: the Tap collector + Engine facade vs the paper-§3 naive
oracle — all four norm passes (norms-only, grads+norms, clipped,
sharded), the scan/checkpoint carry contract, accumulator layouts,
and the trace-time validation satellites."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import pex
from repro.core import naive
from repro.core.engine import Engine, infer_batch_size
from repro.core.taps import NULL, PexSpec, Tap
from repro.dist import sharding as shd

B, S, D, H, V = 4, 6, 8, 10, 12


def _toy(seed=0):
    rng = np.random.default_rng(seed)
    params = {
        "emb": jnp.asarray(rng.normal(size=(V, D)), jnp.float32) * 0.3,
        "w1": jnp.asarray(rng.normal(size=(D, H)), jnp.float32) * 0.3,
        "b1": jnp.asarray(rng.normal(size=(H,)), jnp.float32) * 0.1,
        "g": jnp.asarray(rng.normal(size=(H,)), jnp.float32) * 0.5 + 1.0,
        "w2": jnp.asarray(rng.normal(size=(H, V)), jnp.float32) * 0.3,
    }
    batch = {"ids": jnp.asarray(rng.integers(0, V, size=(B, S))),
             "labels": jnp.asarray(rng.integers(0, V, size=(B, S)))}
    return params, batch


def _loss_v2(p, b, tap):
    """v2 canonical loss: every op registers with the tap collector,
    and the per-token loss map with ``tap.token_loss`` (plan layer)."""
    h = tap.embedding(p["emb"], b["ids"])
    z = tap.dense(h, p["w1"])
    z = tap.bias_add(z, p["b1"])
    h = jax.nn.gelu(z)
    h = tap.scale(h, p["g"])
    logits = tap.dense(h, p["w2"])
    logp = jax.nn.log_softmax(logits)
    ll = jnp.take_along_axis(logp, b["labels"][..., None], -1)[..., 0]
    token_losses = tap.token_loss(-ll)
    return jnp.sum(token_losses, axis=-1), {}


def _oracle(params, batch, param_filter=None):
    def single(p, ex):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return _loss_v2(p, b1, NULL)[0][0]
    return naive.per_example_sq_norms(single, params, batch, param_filter)


def _one_device_mesh():
    return shd.make_mesh((1, 1), ("data", "model"))


# --- the four norm passes --------------------------------------------------

@pytest.mark.parametrize("method", ["gram", "direct", "auto"])
def test_engine_norms_only_exact(method):
    params, batch = _toy()
    eng = Engine(PexSpec(method=method))
    res = eng.value_and_norms(_loss_v2, params, batch)
    np.testing.assert_allclose(jnp.sum(res.sq_norms, -1),
                               _oracle(params, batch), rtol=2e-5)


def test_engine_grads_and_norms_exact():
    params, batch = _toy()
    eng = Engine(PexSpec(method="gram"))
    res = jax.jit(lambda p, b: eng.value_grads_and_norms(_loss_v2, p, b))(
        params, batch)
    np.testing.assert_allclose(jnp.sum(res.sq_norms, -1),
                               _oracle(params, batch), rtol=2e-5)
    g = jax.grad(lambda p: jnp.sum(_loss_v2(p, batch, NULL)[0]))(params)
    for k in params:
        np.testing.assert_allclose(res.grads[k], g[k], rtol=1e-5, atol=1e-6)


def test_engine_clipped_step_exact():
    params, batch = _toy()
    clip = 0.5
    eng = Engine(PexSpec(method="gram"), clip_norm=clip)
    res = eng.clipped_step(_loss_v2, params, batch)

    def single(p, ex):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return _loss_v2(p, b1, NULL)[0][0]

    oracle = _oracle(params, batch)
    pex_g = naive.per_example_grads(single, params, batch)
    c = jnp.minimum(1.0, clip / (jnp.sqrt(oracle) + 1e-6))
    for k in params:
        want = jnp.einsum("b,b...->...", c, pex_g[k])
        np.testing.assert_allclose(res.grads[k], want, rtol=1e-4, atol=1e-6)


def test_engine_sharded_matches_local():
    """Engine(mesh=...) must agree with Engine() on a trivial mesh for
    every pass (multi-way extents run in the selfcheck subprocess)."""
    params, batch = _toy()
    local = Engine(PexSpec(method="gram"), clip_norm=1.0)
    mesh = Engine(PexSpec(method="gram"), clip_norm=1.0,
                  mesh=_one_device_mesh())
    ref = local.value_grads_and_norms(_loss_v2, params, batch)
    got = mesh.value_grads_and_norms(_loss_v2, params, batch)
    np.testing.assert_allclose(ref.loss, got.loss, rtol=1e-6)
    np.testing.assert_allclose(ref.sq_norms, got.sq_norms, rtol=1e-6)
    for k in params:
        np.testing.assert_allclose(ref.grads[k], got.grads[k], rtol=1e-6)
    ref_n = local.value_and_norms(_loss_v2, params, batch)
    got_n = mesh.value_and_norms(_loss_v2, params, batch)
    np.testing.assert_allclose(ref_n.sq_norms, got_n.sq_norms, rtol=1e-6)
    ref_c = local.clipped_step(_loss_v2, params, batch)
    got_c = mesh.clipped_step(_loss_v2, params, batch)
    for k in params:
        np.testing.assert_allclose(ref_c.grads[k], got_c.grads[k], rtol=1e-6)


# --- scan / checkpoint carry contract --------------------------------------

def test_tap_under_jit_scan_remat():
    """pex.scan(remat=True) threads the collector's accumulator through
    the scan carry and jax.checkpoint; norms stay exact under jit."""
    rng = np.random.default_rng(2)
    params = {"emb": jnp.asarray(rng.normal(size=(V, D)), jnp.float32) * .3,
              "ws": jnp.asarray(rng.normal(size=(3, D, D)), jnp.float32) * .3,
              "wo": jnp.asarray(rng.normal(size=(D, V)), jnp.float32) * .3}
    batch = {"ids": jnp.asarray(rng.integers(0, V, size=(B, S))),
             "labels": jnp.asarray(rng.integers(0, V, size=(B, S)))}

    def loss_fn(p, b, tap):
        h = tap.embedding(p["emb"], b["ids"])

        def blk(h, w):
            z = tap.dense(h, w)
            return jnp.tanh(z) + h, None

        h, _ = pex.scan(blk, h, p["ws"], tap=tap, remat=True)
        logits = tap.dense(h, p["wo"])
        logp = jax.nn.log_softmax(logits)
        ll = jnp.take_along_axis(logp, b["labels"][..., None], -1)[..., 0]
        return -jnp.sum(ll, -1), {}

    eng = Engine(PexSpec(method="gram"))
    sq = jax.jit(lambda p, b: eng.value_and_norms(loss_fn, p, b).sq_norms)(
        params, batch)

    def single(p, ex):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return loss_fn(p, b1, NULL)[0][0]

    oracle = naive.per_example_sq_norms(single, params, batch)
    np.testing.assert_allclose(jnp.sum(sq, -1), oracle, rtol=2e-5)


def test_tap_checkpoint_helper():
    """pex.checkpoint makes the accumulator explicit across a remat
    boundary in straight-line (unrolled) code."""
    rng = np.random.default_rng(3)
    params = {"w1": jnp.asarray(rng.normal(size=(D, D)), jnp.float32) * .4,
              "w2": jnp.asarray(rng.normal(size=(D, D)), jnp.float32) * .4}
    batch = {"x": jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32),
             "y": jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)}

    def loss_fn(p, b, tap):
        def block(h, w):
            return jnp.tanh(tap.dense(h, w)), None

        h = b["x"]
        for k in ("w1", "w2"):
            fn = pex.checkpoint(block, tap=tap)
            h, _ = fn(h, p[k])
        return jnp.sum(jnp.square(h - b["y"]), axis=(1, 2)), {}

    eng = Engine(PexSpec(method="gram"))
    res = jax.jit(lambda p, b: eng.value_grads_and_norms(loss_fn, p, b))(
        params, batch)

    def single(p, ex):
        b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
        return loss_fn(p, b1, NULL)[0][0]

    oracle = naive.per_example_sq_norms(single, params, batch)
    np.testing.assert_allclose(jnp.sum(res.sq_norms, -1), oracle, rtol=2e-5)
    g = jax.grad(lambda p: jnp.sum(loss_fn(p, batch, NULL)[0]))(params)
    for k in params:
        np.testing.assert_allclose(res.grads[k], g[k], rtol=1e-5, atol=1e-6)


# --- layouts ----------------------------------------------------------------

def test_token_granularity_sums_bias_scale_embed():
    """TokenLayout covers bias/scale/embedding taps too: summing the
    (B, S) map over groups of ops equals the per-token contribution
    norms from perturbation-tap oracles."""
    rng = np.random.default_rng(7)
    params = {"emb": jnp.asarray(rng.normal(size=(V, D)), jnp.float32) * .5,
              "b": jnp.asarray(rng.normal(size=(D,)), jnp.float32) * .2,
              "g": jnp.asarray(rng.normal(size=(D,)), jnp.float32) + 1.0,
              "w": jnp.asarray(rng.normal(size=(D, V)), jnp.float32) * .4}
    batch = {"ids": jnp.asarray(rng.integers(0, V, size=(B, S))),
             "labels": jnp.asarray(rng.integers(0, V, size=(B, S)))}

    def loss_fn(p, b, tap):
        h = tap.embedding(p["emb"], b["ids"])
        h = tap.bias_add(h, p["b"])
        h = tap.scale(jnp.tanh(h), p["g"])
        logits = tap.dense(h, p["w"])
        logp = jax.nn.log_softmax(logits)
        ll = jnp.take_along_axis(logp, b["labels"][..., None], -1)[..., 0]
        return -jnp.sum(ll, -1), {}

    eng = Engine(PexSpec(), granularity="token")
    res = eng.value_and_norms(loss_fn, params, batch)
    assert res.sq_norms.shape == (B, S)

    # oracle: z̄ of every tapped op's output via perturbation taps
    def f(tp):
        h = params["emb"][batch["ids"]] + tp["emb"]
        h = h + params["b"] + tp["bias"]
        h = jnp.tanh(h) * params["g"] + tp["scale"]
        logits = h @ params["w"] + tp["dense"]
        logp = jax.nn.log_softmax(logits)
        ll = jnp.take_along_axis(logp, batch["labels"][..., None],
                                 -1)[..., 0]
        return -jnp.sum(ll)

    tp0 = {"emb": jnp.zeros((B, S, D)), "bias": jnp.zeros((B, S, D)),
           "scale": jnp.zeros((B, S, D)), "dense": jnp.zeros((B, S, V))}
    zb = jax.grad(f)(tp0)
    h_in = jnp.tanh(params["emb"][batch["ids"]] + params["b"])  # scale input
    h_sc = h_in * params["g"]                                   # dense input
    want = (np.sum(np.square(np.asarray(zb["emb"])), -1)        # ‖h‖²=1
            + np.sum(np.square(np.asarray(zb["bias"])), -1)
            + np.sum(np.square(np.asarray(zb["scale"]) *
                               np.asarray(h_in)), -1)
            + np.sum(np.square(np.asarray(h_sc)), -1) *
            np.sum(np.square(np.asarray(zb["dense"])), -1))
    np.testing.assert_allclose(np.asarray(res.sq_norms), want, rtol=1e-4)


def _ref_moe_token_stats(p, x, cfg):
    """Dispatch-independent per-token oracle for an MoE layer: the
    top-k reference forward (no capacity buffers — valid when nothing
    is dropped) with additive perturbations at every tapped op output;
    token t's stat is Σ_ops ‖h_t‖²·‖z̄_t‖² with z̄ from plain jax.grad
    w.r.t. the perturbations. Loss: Σ_j ‖y_j‖²."""
    from repro.nn.moe import _route
    from repro.nn.mlp import _act
    b, s, d = x.shape
    f = cfg.d_ff
    e = cfg.n_experts

    def fwd(pert):
        logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                            p["router"]["w"]) + pert["router"]
        gates, idx = _route(cfg, logits.reshape(b * s, e))
        gates = gates.reshape(b, s, cfg.top_k)
        idx = idx.reshape(b, s, cfg.top_k)
        y = jnp.zeros_like(x)
        hs = []
        for k in range(cfg.top_k):
            ek = idx[..., k]
            g = jnp.einsum("bsd,bsdf->bsf", x, p["gate"][ek]) + pert[f"g{k}"]
            u = jnp.einsum("bsd,bsdf->bsf", x, p["up"][ek]) + pert[f"u{k}"]
            h = (_act(cfg.act)(g) * u).astype(x.dtype)
            hs.append(h)
            yk = jnp.einsum("bsf,bsfd->bsd", h, p["down"][ek]) + pert[f"d{k}"]
            y = y + gates[..., k, None].astype(x.dtype) * yk
        return jnp.sum(jnp.square(y)), hs

    pert0 = {"router": jnp.zeros((b, s, e), jnp.float32)}
    for k in range(cfg.top_k):
        pert0[f"g{k}"] = jnp.zeros((b, s, f), x.dtype)
        pert0[f"u{k}"] = jnp.zeros((b, s, f), x.dtype)
        pert0[f"d{k}"] = jnp.zeros((b, s, d), x.dtype)
    (total, vjp_fn, hs) = jax.vjp(fwd, pert0, has_aux=True)
    (zb,) = vjp_fn(jnp.ones(()))

    def ssq(a):
        return np.sum(np.square(np.asarray(a, np.float64)), -1)

    want = ssq(x.astype(jnp.float32)) * ssq(zb["router"])
    for k in range(cfg.top_k):
        want = want + ssq(x) * (ssq(zb[f"g{k}"]) + ssq(zb[f"u{k}"]))
        want = want + ssq(hs[k]) * ssq(zb[f"d{k}"])
    return want


@pytest.mark.parametrize("groups", [1, 2])
def test_token_layout_expert_taps_exact(groups):
    """Engine(granularity='token') over an MoE layer: the (B, S) map
    must match the dispatch-independent top-k oracle exactly — the
    capacity shuffle carries token positions through to the expert taps
    (ROADMAP follow-up; formerly a trace-time rejection)."""
    from repro.nn.moe import MoeCfg, init_moe, moe
    from repro.nn.param import unbox

    cfg = MoeCfg(d_model=8, d_ff=6, n_experts=4, top_k=2,
                 capacity_factor=8.0,  # no drops ⇒ oracle computes the
                 dispatch_groups=groups)  # same function
    p = unbox(init_moe(jax.random.PRNGKey(3), cfg, dtype=jnp.float32))
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.normal(size=(B, 6, cfg.d_model)), jnp.float32)

    def loss_fn(params, b, tap):
        y = moe(params, b["x"], tap=tap, cfg=cfg)
        return jnp.sum(jnp.square(y), axis=(1, 2)), {}

    eng = Engine(PexSpec(), granularity="token")
    res = jax.jit(lambda pp, bb: eng.value_and_norms(loss_fn, pp, bb)
                  .sq_norms)(p, {"x": x})
    want = _ref_moe_token_stats(p, x, cfg)
    np.testing.assert_allclose(np.asarray(res), want, rtol=1e-4)

    # the per-example layout on the same model stays exact too (guards
    # the composite-segment path against the tok threading change)
    res_ex = Engine(PexSpec()).value_and_norms(loss_fn, p, {"x": x})

    def single(pp, ex):
        b1 = jax.tree_util.tree_map(lambda v: v[None], ex)
        return loss_fn(pp, b1, NULL)[0][0]

    oracle = naive.per_example_sq_norms(single, p, {"x": x})
    np.testing.assert_allclose(np.asarray(jnp.sum(res_ex.sq_norms, -1)),
                               np.asarray(oracle), rtol=2e-4)


def test_token_layout_expert_taps_need_positions():
    """An expert tap at token granularity without a slot→token table
    must fail at trace time (the capacity shuffle loses positions)."""
    tap = Tap(PexSpec(), acc=pex.TokenLayout(4).init(2),
              layout=pex.TokenLayout(4))
    x = jnp.zeros((2, 4, 3))
    w = jnp.zeros((2, 3, 5))
    seg = jnp.zeros((2, 4), jnp.int32)
    with pytest.raises(ValueError, match="token positions"):
        tap.dense_expert(x, w, seg)


# --- validation satellites --------------------------------------------------

def test_unknown_group_raises_at_trace_time():
    """A typo'd group must not silently corrupt column 0 when the spec
    has dedicated (non-catch-all) columns."""
    spec = PexSpec(groups=("attn", "mlp"))
    tap = Tap(spec, acc=pex.ExampleLayout(2).init(B))
    h = jnp.ones((B, D))
    w = jnp.ones((D, H))
    with pytest.raises(ValueError, match="unknown pex group"):
        tap.dense(h, w, group="mpl")
    # exact names and catch-alls still resolve
    assert spec.group_index("mlp") == 1
    assert PexSpec(groups=("all",)).group_index("mpl") == 0
    assert PexSpec(groups=("attn", "other")).group_index("mpl") == 1


def test_noise_without_rng_raises():
    params, batch = _toy()
    eng = Engine(PexSpec(), clip_norm=1.0, noise_std=0.5)
    with pytest.raises(ValueError, match="noise_std"):
        eng.clipped_step(_loss_v2, params, batch)
    from repro.core import passes

    def acc_loss(p, acc, b):
        tap = Tap(PexSpec(), acc=acc)
        lv, aux = _loss_v2(p, b, tap)
        return lv, tap.carry(), aux

    with pytest.raises(ValueError, match="noise_std"):
        passes.clipped_value_and_grads(acc_loss, params, batch, PexSpec(), B,
                                       1.0, noise_std=0.5, noise_rng=None)


def test_infer_batch_size():
    assert infer_batch_size({"a": jnp.zeros((5, 2))}) == 5
    with pytest.raises(ValueError):
        infer_batch_size({"a": jnp.zeros((5, 2)), "b": jnp.zeros((3,))})


def test_engine_granularity_validation():
    with pytest.raises(ValueError):
        Engine(PexSpec(), granularity="word")
    params, batch = _toy()
    eng = Engine(PexSpec(), granularity="token", clip_norm=1.0)
    # clipped_step on a token engine IS per-token clipping now
    # (tests/test_plan.py checks it against the per-token oracle)
    res = eng.clipped_step(_loss_v2, params, batch)
    assert res.sq_norms.shape == (B, S)
    with pytest.raises(NotImplementedError):
        eng.gradient_noise_scale(_loss_v2, params, batch)


def test_engine_gradient_noise_scale_runs():
    params, batch = _toy()
    eng = Engine(PexSpec(method="gram"))
    gns = eng.gradient_noise_scale(_loss_v2, params, batch)
    assert np.isfinite(float(gns))


def test_null_tap_is_plain():
    params, batch = _toy()
    lv, aux = _loss_v2(params, batch, NULL)
    assert lv.shape == (B,)
    assert NULL.carry() is None
