"""Consumer plans (core.plan / Engine.step, DESIGN.md §9): the fused
pass vs the naive per-example oracle and vs sequential Engine calls;
per-token Clip vs a naive per-token oracle (transformer-style toy and
MoE expert taps), local and under shard_map; plan-analysis validation;
and the importance satellites (degenerate pools, scalar-leaf gather)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import pex
from repro.core import importance, naive
from repro.core import plan as plan_mod
from repro.core.engine import Engine
from repro.core.passes import add_grad_noise
from repro.core.taps import NULL, PexSpec
from repro.dist import sharding as shd

B, S, D, H, V = 4, 6, 8, 10, 12


def _toy(seed=0):
    rng = np.random.default_rng(seed)
    params = {
        "emb": jnp.asarray(rng.normal(size=(V, D)), jnp.float32) * 0.3,
        "w1": jnp.asarray(rng.normal(size=(D, H)), jnp.float32) * 0.3,
        "b1": jnp.asarray(rng.normal(size=(H,)), jnp.float32) * 0.1,
        "g": jnp.asarray(rng.normal(size=(H,)), jnp.float32) * 0.5 + 1.0,
        "w2": jnp.asarray(rng.normal(size=(H, V)), jnp.float32) * 0.3,
    }
    batch = {"ids": jnp.asarray(rng.integers(0, V, size=(B, S))),
             "labels": jnp.asarray(rng.integers(0, V, size=(B, S)))}
    return params, batch


def _loss_v2(p, b, tap):
    """Canonical v2 loss incl. the per-token loss-map registration.
    The cumsum mixes tokens (a stand-in for attention), so per-token
    loss reweighting is NOT per-token gradient scaling — the oracle
    must differentiate the reweighted loss like the plan does."""
    h = tap.embedding(p["emb"], b["ids"])
    z = tap.dense(h, p["w1"])
    z = tap.bias_add(z, p["b1"])
    h = jax.nn.gelu(jnp.cumsum(z, axis=1))
    h = tap.scale(h, p["g"])
    logits = tap.dense(h, p["w2"])
    logp = jax.nn.log_softmax(logits)
    ll = jnp.take_along_axis(logp, b["labels"][..., None], -1)[..., 0]
    token_losses = tap.token_loss(-ll)
    return jnp.sum(token_losses, axis=-1), {}


def _single(p, ex):
    b1 = jax.tree_util.tree_map(lambda x: x[None], ex)
    return _loss_v2(p, b1, NULL)[0][0]


def _one_device_mesh():
    return shd.make_mesh((1, 1), ("data", "model"))


# --- the fused pass vs oracles and vs sequential calls ----------------------

def test_fused_clip_noise_gns_exact_vs_naive_oracle():
    """step([Clip, Noise, GNS]) == naive per-example clip + the same
    noise + the GNS formula on the clipped estimator's quantities."""
    params, batch = _toy()
    clip, sigma = 0.5, 0.3
    key = jax.random.PRNGKey(1)
    eng = Engine(PexSpec(method="gram"))
    res = jax.jit(lambda p, b: eng.step(
        _loss_v2, p, b, consumers=[pex.Clip(clip), pex.Noise(sigma, key),
                                   pex.GNS()]))(params, batch)

    sq = naive.per_example_sq_norms(_single, params, batch)
    np.testing.assert_allclose(jnp.sum(res.sq_norms, -1), sq, rtol=2e-5)
    pg = naive.per_example_grads(_single, params, batch)
    c = jnp.minimum(1.0, clip / (jnp.sqrt(sq) + 1e-6))
    np.testing.assert_allclose(res.clip_coef, c, rtol=1e-5)
    np.testing.assert_allclose(res.weights, c, rtol=1e-5)
    want = {k: jnp.einsum("b,b...->...", c, pg[k]) for k in params}
    gns_want = plan_mod.gradient_noise_scale(
        jnp.square(c) * sq, want, batch_size=B)
    np.testing.assert_allclose(res.gns, gns_want, rtol=1e-4)
    want = add_grad_noise(want, sigma, clip, key)   # same key ⇒ same noise
    for k in params:
        np.testing.assert_allclose(res.grads[k], want[k], rtol=1e-4,
                                   atol=1e-5)


def test_fused_matches_sequential_engine_calls():
    """The fused plan returns exactly what the separate fixed-function
    calls it replaces return (clipped grads, norms, GNS)."""
    params, batch = _toy()
    eng = Engine(PexSpec(method="gram"), clip_norm=0.5)
    fused = jax.jit(lambda p, b: eng.step(
        _loss_v2, p, b, consumers=[pex.Clip(0.5), pex.GNS()]))(params, batch)
    seq_clip = eng.clipped_step(_loss_v2, params, batch)
    np.testing.assert_allclose(fused.sq_norms, seq_clip.sq_norms, rtol=1e-6)
    for k in params:
        np.testing.assert_allclose(fused.grads[k], seq_clip.grads[k],
                                   rtol=1e-5, atol=1e-7)
    # sequential GNS runs on the UNWEIGHTED estimator; reproduce the
    # fused (clipped-estimator) number from the sequential outputs
    gns_seq = plan_mod.gradient_noise_scale(
        seq_clip.sq_norms, seq_clip.grads, batch_size=B,
        weights=pex.clip_coefficients(seq_clip.sq_norms, 0.5))
    np.testing.assert_allclose(fused.gns, gns_seq, rtol=1e-5)
    # and the pure-GNS plan equals the old two-call recipe exactly
    gn = eng.value_grads_and_norms(_loss_v2, params, batch)
    np.testing.assert_allclose(
        eng.gradient_noise_scale(_loss_v2, params, batch),
        pex.gradient_noise_scale(gn.sq_norms, gn.grads), rtol=1e-6)


def test_user_loss_weights_fold_into_the_backward():
    params, batch = _toy()
    w = jnp.asarray([0.5, 2.0, 1.0, 0.25], jnp.float32)
    eng = Engine(PexSpec(method="gram"))
    res = eng.step(_loss_v2, params, batch, [pex.Grads()], loss_weights=w)
    want = jax.grad(lambda p: jnp.sum(w * _loss_v2(p, batch, NULL)[0]))(
        params)
    for k in params:
        np.testing.assert_allclose(res.grads[k], want[k], rtol=1e-5,
                                   atol=1e-7)
    # ...and multiply with clip coefficients in one reweighted backward
    res_c = eng.step(_loss_v2, params, batch, [pex.Clip(0.5)],
                     loss_weights=w)
    c = pex.clip_coefficients(res_c.sq_norms, 0.5)
    np.testing.assert_allclose(res_c.weights, w * c, rtol=1e-5)


def test_empty_plan_is_the_plain_forward():
    params, batch = _toy()
    res = Engine(PexSpec()).step(_loss_v2, params, batch, [])
    assert res.grads is None and res.sq_norms is None and res.gns is None
    np.testing.assert_allclose(
        res.loss, jnp.sum(_loss_v2(params, batch, NULL)[0]), rtol=1e-6)


def test_importance_plan_continues_on_the_subbatch():
    """Importance + Grads: norms on the pool, sample, one weighted
    backward on the gathered sub-batch — equal to the hand-rolled
    select → gather → weighted-step recipe with the same key."""
    params, batch = _toy()
    key = jax.random.PRNGKey(3)
    eng = Engine(PexSpec(method="gram"))
    res = jax.jit(lambda p, b: eng.step(
        _loss_v2, p, b, consumers=[pex.Importance(2, smoothing=0.2, rng=key),
                                   pex.Grads()]))(params, batch)
    pool = eng.value_and_norms(_loss_v2, params, batch)
    samp = importance.sample(key, pool.sq_norms, 2, smoothing=0.2)
    np.testing.assert_array_equal(res.sample.indices, samp.indices)
    np.testing.assert_allclose(res.sq_norms, pool.sq_norms, rtol=1e-6)
    np.testing.assert_allclose(
        res.sub_sq_norms, jnp.take(pool.sq_norms, samp.indices, axis=0),
        rtol=1e-6)
    sub = importance.gather_batch(batch, samp.indices)
    want = jax.grad(lambda p: jnp.sum(
        samp.weights * _loss_v2(p, sub, NULL)[0]))(params)
    for k in params:
        np.testing.assert_allclose(res.grads[k], want[k], rtol=1e-4,
                                   atol=1e-6)


def test_importance_composes_with_clip():
    """Clip coefficients on the sub-batch come from the GATHERED pool
    norms (no second norms pass); the backward seed is their product
    with the importance weights."""
    params, batch = _toy()
    key = jax.random.PRNGKey(5)
    eng = Engine(PexSpec(method="gram"))
    res = jax.jit(lambda p, b: eng.step(
        _loss_v2, p, b, consumers=[pex.Importance(3, rng=key),
                                   pex.Clip(0.5)]))(params, batch)
    pool = eng.value_and_norms(_loss_v2, params, batch)
    samp = importance.sample(key, pool.sq_norms, 3)
    sub_sq = jnp.take(pool.sq_norms, samp.indices, axis=0)
    c = pex.clip_coefficients(sub_sq, 0.5)
    np.testing.assert_allclose(res.weights, samp.weights * c, rtol=1e-5)
    sub = importance.gather_batch(batch, samp.indices)
    want = jax.grad(lambda p: jnp.sum(
        samp.weights * c * _loss_v2(p, sub, NULL)[0]))(params)
    for k in params:
        np.testing.assert_allclose(res.grads[k], want[k], rtol=1e-4,
                                   atol=1e-6)


# --- per-token clipping -----------------------------------------------------

def _token_oracle(params, batch, clip):
    """Naive per-token oracle: contribution norms from perturbation
    taps on the total loss (independent of TokenLayout), then the
    gradient of the explicitly token-weighted loss."""
    def f(tp):
        h = params["emb"][batch["ids"]] + tp["emb"]
        z = h @ params["w1"] + tp["d1"]
        zb = z + params["b1"] + tp["bias"]
        hg = jax.nn.gelu(jnp.cumsum(zb, axis=1))
        hs = hg * params["g"] + tp["scale"]
        logits = hs @ params["w2"] + tp["d2"]
        logp = jax.nn.log_softmax(logits)
        ll = jnp.take_along_axis(logp, batch["labels"][..., None],
                                 -1)[..., 0]
        return -jnp.sum(ll), (h, hg, hs)

    tp0 = {"emb": jnp.zeros((B, S, D)), "d1": jnp.zeros((B, S, H)),
           "bias": jnp.zeros((B, S, H)), "scale": jnp.zeros((B, S, H)),
           "d2": jnp.zeros((B, S, V))}
    zb = jax.grad(lambda tp: f(tp)[0])(tp0)
    _, (h, hg, hs) = f(tp0)

    def ssq(a):
        return np.sum(np.square(np.asarray(a, np.float64)), -1)

    s_tok = (ssq(zb["emb"]) + ssq(h) * ssq(zb["d1"]) + ssq(zb["bias"])
             + ssq(np.asarray(zb["scale"]) * np.asarray(hg))
             + ssq(hs) * ssq(zb["d2"]))
    c = jnp.asarray(np.minimum(1.0, clip / (np.sqrt(s_tok) + 1e-6)),
                    jnp.float32)
    grads = jax.grad(lambda p: jnp.sum(
        c * (-jnp.take_along_axis(
            jax.nn.log_softmax(
                ((jax.nn.gelu(jnp.cumsum(
                    p["emb"][batch["ids"]] @ p["w1"] + p["b1"], axis=1))
                  * p["g"]) @ p["w2"])),
            batch["labels"][..., None], -1)[..., 0])))(params)
    return s_tok, c, grads


def test_token_clip_exact_vs_naive_per_token_oracle():
    params, batch = _toy()
    clip = 0.05
    eng = Engine(PexSpec(method="gram"))
    res = jax.jit(lambda p, b: eng.step(
        _loss_v2, p, b,
        consumers=[pex.Clip(clip, granularity="token"), pex.Grads(),
                   pex.Norms()]))(params, batch)
    s_tok, c, grads = _token_oracle(params, batch, clip)
    assert res.sq_norms.shape == (B, S)
    np.testing.assert_allclose(np.asarray(res.sq_norms), s_tok, rtol=1e-4)
    np.testing.assert_allclose(res.token_weights, c, rtol=1e-4)
    for k in params:
        np.testing.assert_allclose(res.grads[k], grads[k], rtol=1e-4,
                                   atol=1e-6)


def test_token_clip_sharded_matches_local():
    params, batch = _toy()
    cons = [pex.Clip(0.05, granularity="token"), pex.Grads()]
    local = jax.jit(lambda p, b: Engine(PexSpec(method="gram")).step(
        _loss_v2, p, b, consumers=cons))(params, batch)
    mesh = jax.jit(lambda p, b: Engine(
        PexSpec(method="gram"), mesh=_one_device_mesh()).step(
        _loss_v2, p, b, consumers=cons))(params, batch)
    np.testing.assert_allclose(mesh.sq_norms, local.sq_norms, rtol=1e-6)
    np.testing.assert_allclose(mesh.token_weights, local.token_weights,
                               rtol=1e-6)
    for k in params:
        np.testing.assert_allclose(mesh.grads[k], local.grads[k],
                                   rtol=1e-6, atol=1e-7)


def test_token_clip_via_token_engine_sugar():
    """Engine(granularity='token').clipped_step IS per-token clipping
    now (formerly a NotImplementedError)."""
    params, batch = _toy()
    eng = Engine(PexSpec(method="gram"), granularity="token",
                 clip_norm=0.05)
    res = eng.clipped_step(_loss_v2, params, batch)
    _, _, grads = _token_oracle(params, batch, 0.05)
    for k in params:
        np.testing.assert_allclose(res.grads[k], grads[k], rtol=1e-4,
                                   atol=1e-6)


def test_token_clip_needs_a_registered_token_map():
    params, batch = _toy()

    def no_map_loss(p, b, tap):
        lv, aux = _loss_v2(p, b, tap)
        tap._token_losses = None   # simulate a loss that never registers
        return lv, aux

    eng = Engine(PexSpec(method="gram"))
    with pytest.raises(ValueError, match="token_loss"):
        eng.step(no_map_loss, params, batch,
                 [pex.Clip(0.1, granularity="token")])


def test_token_clip_moe_exact():
    """Per-token clipping through MoE expert taps: the (B, S) norms
    from the dispatch-position-carrying expert taps drive weights for
    the token-reweighted backward; oracle = dispatch-independent top-k
    reference (norms) + plain grad of the token-weighted loss."""
    from repro.nn.moe import MoeCfg, init_moe, moe
    from repro.nn.param import unbox
    from test_pex_v2 import _ref_moe_token_stats

    cfg = MoeCfg(d_model=8, d_ff=6, n_experts=4, top_k=2,
                 capacity_factor=8.0)   # no drops ⇒ oracle is exact
    p = unbox(init_moe(jax.random.PRNGKey(3), cfg, dtype=jnp.float32))
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.normal(size=(B, 6, cfg.d_model)), jnp.float32)

    def loss_fn(params, b, tap):
        y = moe(params, b["x"], tap=tap, cfg=cfg)
        token_losses = tap.token_loss(jnp.sum(jnp.square(y), axis=-1))
        return jnp.sum(token_losses, axis=-1), {}

    clip = 0.5
    eng = Engine(PexSpec(), granularity="token")
    res = jax.jit(lambda pp, bb: eng.step(
        loss_fn, pp, bb, consumers=[pex.Clip(clip, granularity="token"),
                                    pex.Grads()]))(p, {"x": x})
    s_tok = _ref_moe_token_stats(p, x, cfg)
    np.testing.assert_allclose(np.asarray(res.sq_norms), s_tok, rtol=1e-4)
    c = jnp.asarray(np.minimum(1.0, clip / (np.sqrt(s_tok) + 1e-6)),
                    jnp.float32)

    def weighted(pp):
        y = moe(pp, x, tap=NULL, cfg=cfg)
        return jnp.sum(c * jnp.sum(jnp.square(y), axis=-1))

    want = jax.grad(weighted)(p)
    flat_r = jax.tree_util.tree_leaves_with_path(res.grads)
    flat_w = dict(jax.tree_util.tree_leaves_with_path(want))
    for path, g in flat_r:
        np.testing.assert_allclose(g, flat_w[path], rtol=2e-4, atol=1e-6,
                                   err_msg=str(path))


def test_token_clip_real_transformer():
    """Per-token clipping on a registry transformer (scan + remat +
    attention): the reweighted backward must equal the plain gradient
    of the explicitly token-weighted loss — constructed independently
    by feeding the clip coefficients in as ``label_mask`` (which
    multiplies the per-token losses) on the uninstrumented model."""
    from repro.configs.common import ShapeSpec
    from repro.models import registry
    from repro.nn.param import unbox

    aspec = registry.get("llama3.2-1b")
    cfg = aspec.smoke()
    mod = registry.family_module(aspec)
    params = unbox(mod.init(jax.random.PRNGKey(0), cfg))
    batch = registry.make_train_batch(aspec, cfg,
                                      ShapeSpec("t", "train", 8, 3))
    loss_fn = registry.make_loss_fn_v2(aspec, cfg)

    clip = 1.0
    eng = Engine(PexSpec(method="gram"))
    res = jax.jit(lambda p, b: eng.step(
        loss_fn, p, b, consumers=[pex.Clip(clip, granularity="token"),
                                  pex.Grads()]))(params, batch)
    c = res.token_weights
    assert c.shape == (3, 8) and float(jnp.min(c)) < 1.0

    masked = dict(batch, label_mask=c)
    want = jax.grad(lambda p: jnp.sum(
        loss_fn(p, masked, NULL)[0]))(params)
    flat_r = jax.tree_util.tree_leaves_with_path(res.grads)
    flat_w = dict(jax.tree_util.tree_leaves_with_path(want))
    for path, g in flat_r:
        np.testing.assert_allclose(g, flat_w[path], rtol=2e-4, atol=1e-6,
                                   err_msg=str(path))


# --- sharded plans ----------------------------------------------------------

def test_fused_plan_sharded_matches_local():
    params, batch = _toy()
    key = jax.random.PRNGKey(7)
    cons = [pex.Clip(0.5), pex.Noise(0.2, key), pex.GNS(), pex.Norms()]
    local = jax.jit(lambda p, b: Engine(PexSpec(method="gram")).step(
        _loss_v2, p, b, consumers=cons))(params, batch)
    mesh = jax.jit(lambda p, b: Engine(
        PexSpec(method="gram"), mesh=_one_device_mesh()).step(
        _loss_v2, p, b, consumers=cons))(params, batch)
    np.testing.assert_allclose(mesh.loss, local.loss, rtol=1e-6)
    np.testing.assert_allclose(mesh.sq_norms, local.sq_norms, rtol=1e-6)
    np.testing.assert_allclose(mesh.gns, local.gns, rtol=1e-5)
    for k in params:
        np.testing.assert_allclose(mesh.grads[k], local.grads[k],
                                   rtol=1e-6, atol=1e-7)


def test_importance_plan_sharded_matches_local():
    params, batch = _toy()
    key = jax.random.PRNGKey(9)
    cons = [pex.Importance(2, rng=key), pex.Grads()]
    local = jax.jit(lambda p, b: Engine(PexSpec(method="gram")).step(
        _loss_v2, p, b, consumers=cons))(params, batch)
    mesh = jax.jit(lambda p, b: Engine(
        PexSpec(method="gram"), mesh=_one_device_mesh()).step(
        _loss_v2, p, b, consumers=cons))(params, batch)
    np.testing.assert_array_equal(mesh.sample.indices, local.sample.indices)
    for k in params:
        np.testing.assert_allclose(mesh.grads[k], local.grads[k],
                                   rtol=1e-5, atol=1e-7)


# --- plan analysis validation -----------------------------------------------

def test_plan_validation():
    params, batch = _toy()
    eng = Engine(PexSpec())
    with pytest.raises(ValueError, match="duplicate"):
        eng.step(_loss_v2, params, batch, [pex.Norms(), pex.Norms()])
    with pytest.raises(TypeError, match="unknown consumer"):
        eng.step(_loss_v2, params, batch, ["clip"])
    with pytest.raises(ValueError, match="granularity"):
        pex.Clip(1.0, granularity="word")
    with pytest.raises(ValueError, match="noise_std"):
        eng.step(_loss_v2, params, batch, [pex.Clip(1.0), pex.Noise(0.5)])
    with pytest.raises(ValueError, match="scale"):
        eng.step(_loss_v2, params, batch,
                 [pex.Noise(0.5, jax.random.PRNGKey(0))])
    with pytest.raises(NotImplementedError, match="GNS"):
        eng.step(_loss_v2, params, batch,
                 [pex.Clip(1.0, granularity="token"), pex.GNS()])
    with pytest.raises(NotImplementedError, match="Importance"):
        eng.step(_loss_v2, params, batch,
                 [pex.Clip(1.0, granularity="token"),
                  pex.Importance(2, rng=jax.random.PRNGKey(0))])
    tok_eng = Engine(PexSpec(), granularity="token")
    with pytest.raises(ValueError, match="token"):
        tok_eng.step(_loss_v2, params, batch, [pex.Clip(1.0)])
    # Noise must not default its DP sensitivity to a token Clip's C
    # (per-token clipping bounds each token term, not the example)
    with pytest.raises(ValueError, match="sensitivity"):
        eng.step(_loss_v2, params, batch,
                 [pex.Clip(1.0, granularity="token"),
                  pex.Noise(0.5, jax.random.PRNGKey(0))])
    # Importance without a key fails at analysis, not inside jax.random
    with pytest.raises(ValueError, match="rng"):
        eng.step(_loss_v2, params, batch, [pex.Importance(2), pex.Grads()])
    # standalone Noise with an explicit scale is fine
    res = eng.step(_loss_v2, params, batch,
                   [pex.Noise(0.1, jax.random.PRNGKey(0), scale=1.0)])
    assert res.grads is not None


def test_trainer_accepts_gns_as_gradient_consumer():
    """(Norms, GNS) is a valid training plan — GNS demands the
    gradient, so the fused step produces one for the optimizer."""
    from repro.data.pipeline import DataConfig
    from repro.optim import adamw
    from repro.train.trainer import TrainConfig, Trainer
    t = Trainer(_loss_v2, _toy()[0], PexSpec(method="gram"),
                adamw.AdamWConfig(lr=1e-3),
                TrainConfig(consumers=(pex.Norms(), pex.GNS()), steps=2,
                            log_every=0),
                DataConfig(vocab=V, seq=S, global_batch=B))
    ms = t.train()
    assert len(ms) == 2 and np.isfinite(ms[-1]["gns"])


# --- importance satellites --------------------------------------------------

def test_sampling_distribution_degenerate_pool_falls_back_uniform():
    n = 8
    with pytest.warns(RuntimeWarning, match="uniform"):
        p = importance.sampling_distribution(jnp.zeros((n,)))
    np.testing.assert_allclose(p, np.full(n, 1.0 / n), rtol=1e-6)
    with pytest.warns(RuntimeWarning, match="uniform"):
        p = importance.sampling_distribution(
            jnp.asarray([1.0, np.nan, 2.0, 1.0]))
    np.testing.assert_allclose(p, np.full(4, 0.25), rtol=1e-6)
    # under jit: same numbers (the warning becomes a debug print)
    p = jax.jit(importance.sampling_distribution)(jnp.zeros((n,)))
    np.testing.assert_allclose(p, np.full(n, 1.0 / n), rtol=1e-6)
    # sampling from the fallback works
    s = importance.sample(jax.random.PRNGKey(0), jnp.zeros((n, 2)), 3)
    assert s.indices.shape == (3,)
    assert np.all(np.isfinite(np.asarray(s.weights)))
    # healthy pools are untouched
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        p = importance.sampling_distribution(jnp.asarray([1.0, 4.0]))
    np.testing.assert_allclose(p, [1.0 / 3.0, 2.0 / 3.0], rtol=1e-6)


def test_gather_batch_skips_scalar_and_static_leaves():
    batch = {"ids": jnp.arange(12).reshape(4, 3),
             "step": jnp.asarray(7),           # 0-d array
             "flag": True,                      # python scalar
             "temp": 0.5}
    out = importance.gather_batch(batch, jnp.asarray([2, 0]))
    np.testing.assert_array_equal(out["ids"], [[6, 7, 8], [0, 1, 2]])
    assert int(out["step"]) == 7 and out["step"].ndim == 0
    assert out["flag"] is True and out["temp"] == 0.5

    # a non-batch vector leaf (wrong leading extent) is ambiguous
    # without an explicit batch_size...
    amb = {"ids": jnp.zeros((4, 3)), "scale": jnp.ones((5,))}
    with pytest.raises(ValueError, match="batch_size"):
        importance.gather_batch(amb, jnp.asarray([0]))
    # ...and passes through untouched with one
    out = importance.gather_batch(amb, jnp.asarray([1, 3]), batch_size=4)
    assert out["ids"].shape == (2, 3)
    assert out["scale"].shape == (5,)


def test_step_result_fields_default_none():
    params, batch = _toy()
    res = Engine(PexSpec()).step(_loss_v2, params, batch, [pex.Norms()])
    assert res.grads is None and res.gns is None and res.sample is None
    assert res.weights is None and res.token_weights is None
    assert res.sq_norms.shape == (B, 1)
