"""Soak harness: fault plans, the supervisor state machine, graceful
degradation (quarantine), resume validation, and the end-to-end storm.

The e2e storm runs in a subprocess: the simulated N-host world needs
forced host devices, which must be configured before jax initializes —
impossible inside a pytest process whose jax is already live.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ft
from repro.core import plan as plan_mod
from repro.core.taps import PexSpec
from repro.data.pipeline import (DataConfig, LogicalShardedLM,
                                 PipelineState, assign_logical_shards)
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer

SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), os.pardir, "src"))


# --- fault plans -----------------------------------------------------------

def test_fault_plan_scripted_and_random_deterministic():
    a = ft.scripted_storm("short", 8, 40)
    assert a == ft.scripted_storm("short", 8, 40)
    kinds = {e.kind for e in a.events}
    assert {"host_death", "ckpt_corrupt", "nan_batch", "host_return",
            "straggler", "tmp_litter"} <= kinds
    r = ft.random_storm(7, 8, 64)
    assert r == ft.random_storm(7, 8, 64)
    assert r != ft.random_storm(8, 8, 64)


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="killed twice"):
        ft.FaultPlan((ft.FaultEvent(1, "host_death", host=0),
                      ft.FaultEvent(2, "host_death", host=0))
                     ).validate(4, 10)
    with pytest.raises(ValueError, match="outside"):
        ft.FaultPlan((ft.FaultEvent(1, "host_death", host=9),)
                     ).validate(4, 10)
    with pytest.raises(ValueError, match="unknown fault kind"):
        ft.FaultEvent(1, "meteor_strike")
    with pytest.raises(ValueError, match="power-of-two"):
        ft.scripted_storm("short", 3, 40)
    with pytest.raises(ValueError, match="steps"):
        ft.scripted_storm("short", 8, 10)


def test_poison_vector_identity_and_nan():
    plan = ft.FaultPlan((ft.FaultEvent(5, "nan_batch", examples=(1, 3)),))
    np.testing.assert_array_equal(plan.poison_vector(4, 6),
                                  np.ones(6, np.float32))
    v = plan.poison_vector(5, 6)
    assert np.isnan(v[[1, 3]]).all()
    assert np.isfinite(v[[0, 2, 4, 5]]).all()
    with pytest.raises(ValueError, match="outside"):
        plan.poison_vector(5, 2)


def test_poison_loss_fn_is_bit_exact_identity():
    def loss(params, batch, tap):
        return batch["x"] * params, None

    wrapped = ft.poison_loss_fn(loss)
    x = jnp.asarray([0.3, 0.7, 1.9])
    base, _ = loss(2.0, {"x": x}, None)
    same, _ = wrapped(2.0, {"x": x, "poison": jnp.ones(3)}, None)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(same))
    bad, _ = wrapped(
        2.0, {"x": x, "poison": jnp.asarray([1.0, np.nan, 1.0])}, None)
    bad = np.asarray(bad)
    assert np.isnan(bad[1]) and np.isfinite(bad[[0, 2]]).all()


# --- data: the logical shard grid (INV2's anchor) --------------------------

def test_logical_shards_invariant_under_renumbering():
    cfg = DataConfig(vocab=64, seq=8, global_batch=16, seed=1)
    lm = LogicalShardedLM(cfg, n_logical=8)
    want = np.asarray(lm.global_batch_at(3)["ids"])
    for hosts in ([0, 1, 2, 3, 4, 5, 6, 7], [0, 3, 4, 6], [1, 5], [2]):
        owned = assign_logical_shards(8, hosts)
        got = np.asarray(lm.global_batch_at(3, owned)["ids"])
        np.testing.assert_array_equal(want, got)
    # a non-order-preserving assignment IS visible in the stream —
    # which is exactly what the soak's data-replay invariant catches
    got = np.asarray(
        lm.global_batch_at(3, {0: [4, 5, 6, 7], 1: [0, 1, 2, 3]})["ids"])
    assert not np.array_equal(want, got)
    with pytest.raises(ValueError, match="divide"):
        assign_logical_shards(8, [0, 1, 2])


def test_pipeline_state_roundtrip_and_validation():
    ps = PipelineState(step=7, seed=3)
    assert PipelineState.from_dict(ps.to_dict()) == ps
    with pytest.raises(ValueError, match="missing"):
        PipelineState.from_dict({"step": 7})


# --- supervisor state machine ----------------------------------------------

class _Recorder(ft.RecoveryActions):
    def __init__(self, fail: bool = False):
        self.calls = []
        self.fail = fail

    def restore_to(self, topology, active_hosts, reason):
        if self.fail:
            raise RuntimeError("restore failed")
        self.calls.append((reason, topology.n_hosts, list(active_hosts)))


def _world(tmp_path, n=4):
    cfg = ft.HeartbeatConfig(interval_s=1.0, deadline_s=2.5)
    mons = {h: ft.HeartbeatMonitor(str(tmp_path), h, cfg)
            for h in range(n)}
    sup_mon = ft.HeartbeatMonitor(str(tmp_path), n, cfg)  # never beats
    return mons, sup_mon


def test_supervisor_contracts_on_dead_host(tmp_path):
    mons, sup_mon = _world(tmp_path)
    rec = _Recorder()
    sup = ft.Supervisor(ft.Topology(4, 1, 1), [0, 1, 2, 3], sup_mon, rec)
    for h in (0, 1, 3):                     # host 2 never heartbeats
        mons[h].beat(step=0, now=0.0)
    events = sup.tick(0.0)
    assert [e.kind for e in events] == ["dead", "contract"]
    assert rec.calls == [("contract", 2, [0, 1])]
    assert sup.active == [0, 1] and sup.topo.n_hosts == 2
    assert sup.state == "RUNNING"


def test_supervisor_contracts_on_torn_heartbeat(tmp_path):
    mons, sup_mon = _world(tmp_path)
    rec = _Recorder()
    sup = ft.Supervisor(ft.Topology(4, 1, 1), [0, 1, 2, 3], sup_mon, rec)
    for h in range(4):
        mons[h].beat(step=0, now=0.0)
    (tmp_path / "host_00003.json").write_text('{"to')   # torn write
    sup.tick(0.5)
    assert rec.calls and rec.calls[0][0] == "contract"
    dead = [e for e in sup.events if e.kind == "dead"]
    assert dead[0].detail["host"] == 3
    assert "Error" in dead[0].detail["error"]   # parse error recorded


def test_supervisor_straggler_grace_then_evict(tmp_path):
    mons, sup_mon = _world(tmp_path)
    rec = _Recorder()
    cfg = ft.SupervisorConfig(straggler_grace=3, allow_expansion=False)
    sup = ft.Supervisor(ft.Topology(4, 1, 1), [0, 1, 2, 3], sup_mon, rec,
                        cfg)
    slow = {0: 1.0, 1: 1.0, 2: 1.0, 3: 8.0}

    def tick(t, times):
        for h in range(4):
            mons[h].beat(step=0, now=t)
        return sup.tick(t, step_times=times)

    for t in (0.0, 1.0):                    # observed, below grace
        tick(t, slow)
        assert sup.state == "DEGRADED" and not rec.calls
    tick(2.0, {h: 1.0 for h in range(4)})   # transient: count resets
    assert sup.state == "RUNNING"
    for t in (3.0, 4.0, 5.0):               # grace consecutive hits
        tick(t, slow)
    assert rec.calls == [("evict", 2, [0, 1])]


def test_supervisor_expands_on_returned_hosts(tmp_path):
    mons, sup_mon = _world(tmp_path)
    rec = _Recorder()
    sup = ft.Supervisor(ft.Topology(2, 1, 1), [0, 1], sup_mon, rec)
    for h in range(4):                      # 2, 3 are fresh spares
        mons[h].beat(step=0, now=0.0)
    events = sup.tick(0.0)
    assert rec.calls == [("expand", 4, [0, 1, 2, 3])]
    assert sup.topo.n_hosts == 4 and sup.active == [0, 1, 2, 3]
    assert "returned" in [e.kind for e in events]


def test_supervisor_halts_below_model_parallel_floor(tmp_path):
    mons, sup_mon = _world(tmp_path)
    rec = _Recorder()
    sup = ft.Supervisor(ft.Topology(4, 1, 4), [0, 1, 2, 3], sup_mon, rec)
    mons[0].beat(step=0, now=0.0)           # hosts 1..3 dead
    with pytest.raises(ft.SupervisorHalted):
        sup.tick(0.0)
    assert sup.state == "HALTED" and not rec.calls
    with pytest.raises(ft.SupervisorHalted):
        sup.tick(1.0)                       # halted worlds stay halted


def test_supervisor_halts_when_recovery_fails(tmp_path):
    mons, sup_mon = _world(tmp_path)
    rec = _Recorder(fail=True)
    sup = ft.Supervisor(ft.Topology(4, 1, 1), [0, 1, 2, 3], sup_mon, rec)
    for h in (0, 1, 2):
        mons[h].beat(step=0, now=0.0)
    with pytest.raises(ft.SupervisorHalted, match="restore failed"):
        sup.tick(0.0)
    assert sup.state == "HALTED"


# --- trainer: resume validation + quarantine -------------------------------

def _toy_trainer(ckpt_dir, seed=0, data_seed=None):
    """Tiny linear model through the real Engine/Trainer machinery."""
    def loss_fn(params, batch, tap):
        x = batch["ids"].astype(jnp.float32)
        pred = x @ params["w"]
        err = pred - batch["labels"].astype(jnp.float32)
        return jnp.mean(jnp.square(err), axis=-1), None

    params = {"w": jnp.eye(4) * 0.5}
    dcfg = DataConfig(vocab=16, seq=4, global_batch=4,
                      seed=seed if data_seed is None else data_seed)
    return Trainer(
        ft.poison_loss_fn(loss_fn), params, PexSpec(enabled=True),
        adamw.AdamWConfig(lr=1e-2),
        TrainConfig(consumers=(plan_mod.Grads(),), steps=4, log_every=0,
                    ckpt_every=10 ** 9, ckpt_dir=ckpt_dir, seed=seed),
        dcfg)


def test_trainer_rejects_incomplete_or_mismatched_resume(tmp_path):
    d = str(tmp_path / "ck")
    t1 = _toy_trainer(d, seed=0)
    t1.save_checkpoint(block=True)
    # trainer seed mismatch: the rng/noise stream would fork
    with pytest.raises(ValueError, match="seed"):
        _toy_trainer(d, seed=1).restore_from()
    # data-stream seed mismatch: different batches would replay
    with pytest.raises(ValueError, match="data stream"):
        _toy_trainer(d, seed=0, data_seed=2).restore_from()
    # a checkpoint with no pipeline state names what's missing
    t1.ckpt.save(99, t1._state_tree(),
                 extra={"step": 99, "opt_step": 0, "seed": 0}, block=True)
    with pytest.raises(ValueError, match=r"missing key\(s\) \['data'\]"):
        _toy_trainer(d, seed=0).restore_from()
    # intact checkpoints restore fine
    assert _toy_trainer(d, seed=0).restore_from(step=0) == 0


def test_trainer_quarantines_poisoned_examples():
    t = _toy_trainer(None)
    batch = dict(t.data.batch_at(0))
    batch["poison"] = jnp.asarray([1.0, np.nan, np.nan, 1.0], jnp.float32)
    before = [np.asarray(x) for x in jax.tree_util.tree_leaves(t.params)]
    m = t.run_step(batch)
    assert m["quarantined"] == 2
    assert t.events[-1]["kind"] == "quarantine"
    assert t.events[-1]["examples"] == [1, 2]
    assert np.isfinite(m["loss"])
    after = [np.asarray(x) for x in jax.tree_util.tree_leaves(t.params)]
    for leaf in after:
        assert np.isfinite(leaf).all()
    # the healthy examples still trained: params moved
    assert any(not np.array_equal(a, b) for a, b in zip(before, after))


def test_trainer_quarantine_matches_clean_step_on_healthy_rows():
    """Quarantining rows ≡ training on a reweighted batch: the poison
    must not leak into the healthy examples' update."""
    t = _toy_trainer(None)
    batch = dict(t.data.batch_at(0))
    batch["poison"] = jnp.asarray([1.0, 1.0, np.nan, 1.0], jnp.float32)
    t.run_step(batch)
    # reference: same step with the bad row explicitly weighted out
    r = _toy_trainer(None)
    clean = dict(r.data.batch_at(0))
    clean["poison"] = jnp.ones(4, jnp.float32)
    sub = jax.tree_util.tree_map(
        lambda x: x.at[2].set(x[0]) if hasattr(x, "at") and x.shape
        and x.shape[0] == 4 else x, clean)
    if r._step_fn_weighted is None:
        r._step_fn_weighted = r._build_step(weighted=True)
    r.rng, key = jax.random.split(r.rng)
    p, o, e, _ = r._step_fn_weighted(
        r.params, r.opt_state, r.err, sub, key,
        jnp.asarray([1.0, 1.0, 0.0, 1.0]))
    for a, b in zip(jax.tree_util.tree_leaves(t.params),
                    jax.tree_util.tree_leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_skips_step_when_every_example_is_poisoned():
    t = _toy_trainer(None)
    batch = dict(t.data.batch_at(0))
    batch["poison"] = jnp.full(4, np.nan, jnp.float32)
    before = [np.asarray(x) for x in jax.tree_util.tree_leaves(t.params)]
    m = t.run_step(batch)
    assert m.get("skipped") == 1
    assert t.events[-1]["kind"] == "skip_step"
    for a, b in zip(before, jax.tree_util.tree_leaves(t.params)):
        np.testing.assert_array_equal(a, np.asarray(b))


# --- the storm, end to end -------------------------------------------------

def _run_soak(*extra):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.soak", "--hosts", "4",
         "--steps", "24", "--seed", "0", "--quiet", *extra],
        capture_output=True, text=True, env=env, timeout=900)


def test_soak_short_storm_end_to_end():
    r = _run_soak()
    assert r.returncode == 0, r.stdout + r.stderr
    summary = json.loads(r.stdout[r.stdout.index("{"):])
    assert summary["invariants"] == "PASS"
    assert summary["contractions"] >= 2
    assert summary["expansions"] >= 1
    assert summary["fallbacks"] >= 1          # corrupt ckpt → fell back
    assert summary["quarantined_steps"]       # NaN batch → quarantine


def test_soak_mutation_checks_trip_their_invariants():
    r = _run_soak("--mutation-check")
    assert r.returncode == 0, r.stdout + r.stderr
    out = json.loads(r.stdout[r.stdout.rindex('{"mutation_check'):])
    assert out["mutation_check"] == {"restore": "bit-exact-restore",
                                     "renumber": "data-replay",
                                     "reshard": "norm-invariance"}
