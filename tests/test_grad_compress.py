"""Error-feedback int8 compression: round-trip quality and the
non-finite-amax guard (a single NaN/inf element must not poison the
whole tensor's scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.grad_compress import (compress_decompress, init_error,
                                       _dequant, _quant)


def _tree(x):
    return {"w": jnp.asarray(x, jnp.float32)}


def test_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = rng.normal(size=(64, 32)).astype(np.float32)
    q, s, finite = _quant(jnp.asarray(g))
    assert bool(finite)
    deq = _dequant(q, s)
    # per-tensor int8: error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) * 0.5 + 1e-7


def test_error_feedback_carries_residual():
    g = _tree([[1.0, -0.3], [0.2, 0.05]])
    err = init_error(g)
    out, new_err = compress_decompress(g, err)
    np.testing.assert_allclose(np.asarray(out["w"] + new_err["w"]),
                               np.asarray(g["w"]), rtol=0, atol=1e-6)


def test_nan_amax_falls_back_to_passthrough():
    g = _tree([[1.0, float("nan")], [0.2, 0.05]])
    err = init_error(g)
    out, new_err = compress_decompress(g, err)
    # the tensor passes through uncompressed: finite entries unchanged,
    # the NaN is preserved for downstream skip logic -- and crucially
    # the OTHER entries did not become NaN via a poisoned scale
    o = np.asarray(out["w"])
    assert np.isnan(o[0, 1])
    np.testing.assert_allclose(o[0, 0], 1.0, atol=1e-6)
    np.testing.assert_allclose(o[1, 0], 0.2, atol=1e-6)
    # and the error carry is cleared, not NaN-contaminated
    assert np.all(np.asarray(new_err["w"]) == 0.0)


def test_inf_amax_falls_back_to_passthrough():
    g = _tree([[jnp.inf, 2.0]])
    out, new_err = compress_decompress(g, init_error(g))
    o = np.asarray(out["w"])
    assert np.isinf(o[0, 0])
    np.testing.assert_allclose(o[0, 1], 2.0, atol=1e-6)
    assert np.all(np.asarray(new_err["w"]) == 0.0)


def test_bad_step_does_not_poison_next_step():
    g_bad = _tree([[float("nan"), 1.0]])
    g_good = _tree([[0.5, 1.0]])
    err = init_error(g_bad)
    _, err = compress_decompress(g_bad, err)
    out, err2 = compress_decompress(g_good, err)
    assert np.all(np.isfinite(np.asarray(out["w"])))
    assert np.all(np.isfinite(np.asarray(err2["w"])))


def test_finite_tensors_unaffected_by_guard():
    rng = np.random.default_rng(1)
    g = _tree(rng.normal(size=(16, 16)))
    out_g, err_g = compress_decompress(g, init_error(g))
    # guard is a no-op on finite input: reconstruction is exact
    np.testing.assert_allclose(np.asarray(out_g["w"] + err_g["w"]),
                               np.asarray(g["w"]), atol=1e-6)
